"""Dispatch for paged attention: in-place block reads vs gather.

``impl`` selects the algorithm family (decode default from
``repro.flags.paged_attention_impl`` — env ``REPRO_PAGED_ATTN_IMPL``;
prefill spans from ``paged_prefill_impl`` — ``REPRO_PAGED_PREFILL_IMPL``,
falling back to the decode env):

* ``"pallas"`` — read KV blocks in place (O(live tokens) traffic):
    - TPU backend: the compiled Pallas kernels (``kernel.py`` for decode,
      ``prefill.py`` for spans);
    - CPU with ``JAX_PALLAS_INTERPRET=1``: the same kernels in interpret
      mode (CI parity coverage of the kernel code itself);
    - CPU otherwise: an XLA twin — a ``fori_loop`` over live blocks whose
      trip count is traced (the step compiles ONCE regardless of
      occupancy) with the identical online-softmax accumulation.  It
      keeps the O(live) property and is what benchmarks measure off-TPU.
* ``"ref"`` — the original full-view gather path (``ref.py``), byte-
  compatible with the pre-kernel engine.

All functions take the pool + (B, max_blocks) block table + per-sequence
position vectors (``seq_lens`` for decode, ``starts`` for spans) of
``repro.core.paging`` and are shape-static in everything but the span
length: occupancy changes never recompile.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.flags import paged_attention_impl, paged_prefill_impl
from repro.kernels.paged_attention import kernel as _k
from repro.kernels.paged_attention import prefill as _p
from repro.kernels.paged_attention import ref as _ref

NEG_INF = -1e30


def _resolve(impl: str) -> str:
    if impl == "ref":
        return "ref"
    if impl != "pallas":
        raise ValueError(f"impl must be 'pallas' or 'ref', got {impl!r}")
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("JAX_PALLAS_INTERPRET", "").lower() not in \
            ("", "0", "false"):
        return "pallas_interpret"
    return "blocked"


def resolve_impl(impl: Optional[str]) -> str:
    """'ref' | 'pallas' | 'pallas_interpret' | 'blocked' (effective decode
    path)."""
    return _resolve(paged_attention_impl() if impl is None else impl)


def resolve_prefill_impl(impl: Optional[str]) -> str:
    """Effective PREFILL path — same values as ``resolve_impl`` but
    defaulting from ``repro.flags.paged_prefill_impl``.  An explicit
    ``impl`` (e.g. the engine's ``attn_impl=``) covers both phases."""
    return _resolve(paged_prefill_impl() if impl is None else impl)


def _fold_blocks(n_live, body, init):
    """fori_loop over live blocks; dynamic trip count, static shapes."""
    return jax.lax.fori_loop(0, n_live, body, init)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _blocked_gqa(q, k_pool, v_pool, tables, lens, *, window, softcap):
    """XLA twin of ``kernel.paged_decode_gqa`` (same math, same masks)."""
    B, KVH, G, d = q.shape
    bs = k_pool.shape[1]
    scale = d ** -0.5
    qf = q.astype(jnp.float32)
    n_live = jnp.max(lens) // bs + 1

    def body(j, carry):
        m, l, acc = carry
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)      # (B,)
        kb = k_pool[blk].astype(jnp.float32)      # (B, bs, KVH, d)
        vb = v_pool[blk].astype(jnp.float32)
        s = jnp.einsum("bkgd,btkd->bkgt", qf, kb) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bs + jnp.arange(bs)
        mask = k_pos[None, :] <= lens[:, None]
        if window > 0:
            mask &= (lens[:, None] - k_pos[None, :]) < window
        mask = mask[:, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgt,btkd->bkgd", p, vb)
        return m_new, l, acc

    init = (jnp.full((B, KVH, G), NEG_INF, jnp.float32),
            jnp.zeros((B, KVH, G), jnp.float32),
            jnp.zeros((B, KVH, G, d), jnp.float32))
    m, l, acc = _fold_blocks(n_live, body, init)
    return (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)


def paged_gqa_attend(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, seq_lens: jax.Array, *,
                     window: int = 0, softcap: float = 0.0,
                     impl: Optional[str] = None) -> jax.Array:
    """Decode-step GQA attention through the block table.

    q (B, 1, H, d) model layout; pools (nb, bs, KVH, d); returns
    (B, 1, H, d).  ``seq_lens[b]`` is the query position (see kernel.py's
    addressing contract).  ``impl`` is resolved EAGERLY (env/backend read
    here, not inside the trace) so the jit cache is keyed on the effective
    path — flipping REPRO_PAGED_ATTN_IMPL between calls takes effect.
    """
    return _gqa_jit(q, k_pool, v_pool, block_tables, seq_lens,
                    window=window, softcap=softcap, eff=resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("window", "softcap", "eff"))
def _gqa_jit(q, k_pool, v_pool, block_tables, seq_lens, *,
             window: int, softcap: float, eff: str) -> jax.Array:
    B, S, H, d = q.shape
    KVH = k_pool.shape[2]
    if eff == "ref":
        return _ref.paged_gqa_reference(q, k_pool, v_pool, block_tables,
                                        seq_lens, window=window,
                                        softcap=softcap)
    qg = q[:, 0].reshape(B, KVH, H // KVH, d)            # head-group packing
    if eff == "blocked":
        out = _blocked_gqa(qg, k_pool, v_pool, block_tables, seq_lens,
                           window=window, softcap=softcap)
    else:
        out = _k.paged_decode_gqa(qg, k_pool, v_pool, block_tables,
                                  seq_lens, window=window, softcap=softcap,
                                  interpret=eff == "pallas_interpret")
    return out.reshape(B, 1, H, d)


# ---------------------------------------------------------------------------
# MLA (absorbed latent decode)
# ---------------------------------------------------------------------------

def _blocked_mla(q_lat, q_rope, c_pool, kr_pool, tables, lens, *, scale):
    B, H, L = q_lat.shape
    bs = c_pool.shape[1]
    ql = q_lat.astype(jnp.float32)
    qr = q_rope.astype(jnp.float32)
    n_live = jnp.max(lens) // bs + 1

    def body(j, carry):
        m, l, acc = carry
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)
        cb = c_pool[blk].astype(jnp.float32)             # (B, bs, L)
        krb = kr_pool[blk].astype(jnp.float32)
        s = (jnp.einsum("bhl,btl->bht", ql, cb)
             + jnp.einsum("bhr,btr->bht", qr, krb)) * scale
        k_pos = j * bs + jnp.arange(bs)
        mask = (k_pos[None, :] <= lens[:, None])[:, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bht,btl->bhl", p, cb)
        return m_new, l, acc

    init = (jnp.full((B, H), NEG_INF, jnp.float32),
            jnp.zeros((B, H), jnp.float32),
            jnp.zeros((B, H, L), jnp.float32))
    m, l, acc = _fold_blocks(n_live, body, init)
    return acc / jnp.maximum(l, 1e-20)[..., None]


def paged_mla_attend(q_lat: jax.Array, q_rope: jax.Array, c_pool: jax.Array,
                     kr_pool: jax.Array, block_tables: jax.Array,
                     seq_lens: jax.Array, *, scale: float,
                     impl: Optional[str] = None) -> jax.Array:
    """Absorbed MLA decode ``probs · c`` over the paged latent cache.

    q_lat/q_rope (B, 1, H, ·) -> out_lat (B, 1, H, lora) fp32; the caller
    applies W^UV / W^O (see ``repro.core.mla.mla_decode_paged``).  ``impl``
    resolves eagerly, like ``paged_gqa_attend``.
    """
    return _mla_jit(q_lat, q_rope, c_pool, kr_pool, block_tables, seq_lens,
                    scale=scale, eff=resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("scale", "eff"))
def _mla_jit(q_lat, q_rope, c_pool, kr_pool, block_tables, seq_lens, *,
             scale: float, eff: str) -> jax.Array:
    if eff == "ref":
        return _ref.paged_mla_reference(q_lat, q_rope, c_pool, kr_pool,
                                        block_tables, seq_lens, scale=scale)
    if eff == "blocked":
        out = _blocked_mla(q_lat[:, 0], q_rope[:, 0], c_pool, kr_pool,
                           block_tables, seq_lens, scale=scale)
    else:
        out = _k.paged_decode_mla(q_lat[:, 0], q_rope[:, 0], c_pool,
                                  kr_pool, block_tables, seq_lens,
                                  scale=scale,
                                  interpret=eff == "pallas_interpret")
    return out[:, None]


# ---------------------------------------------------------------------------
# DSA indexer scores
# ---------------------------------------------------------------------------

def _blocked_indexer(q_idx, w_head, k_pool, tables, lens):
    B, Hi, Di = q_idx.shape
    bs = k_pool.shape[1]
    mb = tables.shape[1]
    scale = Di ** -0.5
    qf = q_idx.astype(jnp.float32)
    wf = w_head.astype(jnp.float32)
    n_live = jnp.max(lens) // bs + 1

    def body(j, out):
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)
        kb = k_pool[blk].astype(jnp.float32)             # (B, bs, Di)
        dots = jax.nn.relu(jnp.einsum("bhd,btd->bht", qf, kb)) * scale
        s = jnp.einsum("bht,bh->bt", dots, wf)
        return jax.lax.dynamic_update_slice(out, s, (0, j * bs))

    out0 = jnp.full((B, mb * bs), NEG_INF, jnp.float32)
    return _fold_blocks(n_live, body, out0)


def paged_indexer_scores(q_idx: jax.Array, w_head: jax.Array,
                         k_pool: jax.Array, block_tables: jax.Array,
                         seq_lens: jax.Array, *,
                         impl: Optional[str] = None) -> jax.Array:
    """DSA decode indexer scores in view coordinates (B, mb*bs) fp32.

    q_idx (B, Hi, Di); w_head (B, Hi); k_pool (nb, bs, Di).  Dead blocks
    score NEG_INF under the in-place impls and stale values under ``ref``
    — both are excluded by the selector's causal mask, so top-k is
    identical.  ``impl`` resolves eagerly, like ``paged_gqa_attend``.
    """
    return _indexer_jit(q_idx, w_head, k_pool, block_tables, seq_lens,
                        eff=resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("eff",))
def _indexer_jit(q_idx, w_head, k_pool, block_tables, seq_lens, *,
                 eff: str) -> jax.Array:
    if eff == "ref":
        return _ref.paged_indexer_reference(q_idx, w_head, k_pool,
                                            block_tables, seq_lens)
    if eff == "blocked":
        return _blocked_indexer(q_idx, w_head, k_pool, block_tables,
                                seq_lens)
    return _k.paged_indexer_scores_kernel(
        q_idx, w_head, k_pool, block_tables, seq_lens,
        interpret=eff == "pallas_interpret")


# ===========================================================================
# PREFILL spans: S-token queries at per-sequence start offsets
# ===========================================================================

def _span_n_live(starts, S: int, bs: int):
    """Blocks any span in the batch attends: trip count for the twins."""
    return (jnp.max(starts) + S - 1) // bs + 1


def _blocked_gqa_prefill(q, k_pool, v_pool, tables, starts, *, window,
                         softcap):
    """XLA twin of ``prefill.paged_prefill_gqa`` (same math, same masks).

    q (B, S, KVH, G, d) span queries -> (B, S, KVH, G, d).
    """
    B, S, KVH, G, d = q.shape
    bs = k_pool.shape[1]
    scale = d ** -0.5
    qf = q.astype(jnp.float32)
    qpos = starts[:, None] + jnp.arange(S)[None]          # (B, S)
    n_live = _span_n_live(starts, S, bs)

    def body(j, carry):
        m, l, acc = carry
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)      # (B,)
        kb = k_pool[blk].astype(jnp.float32)      # (B, bs, KVH, d)
        vb = v_pool[blk].astype(jnp.float32)
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kb) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bs + jnp.arange(bs)
        mask = k_pos[None, None, :] <= qpos[:, :, None]
        if window > 0:
            mask &= (qpos[:, :, None] - k_pos[None, None, :]) < window
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bskgt,btkd->bskgd", p, vb)
        return m_new, l, acc

    init = (jnp.full((B, S, KVH, G), NEG_INF, jnp.float32),
            jnp.zeros((B, S, KVH, G), jnp.float32),
            jnp.zeros((B, S, KVH, G, d), jnp.float32))
    m, l, acc = _fold_blocks(n_live, body, init)
    return (acc / jnp.maximum(l, 1e-20)[..., None]).astype(q.dtype)


def paged_gqa_prefill(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      block_tables: jax.Array, starts: jax.Array, *,
                      window: int = 0, softcap: float = 0.0,
                      impl: Optional[str] = None) -> jax.Array:
    """Span-prefill GQA attention through the block table, in place.

    q (B, S, H, d) model layout — query i of row b sits at absolute
    position ``starts[b] + i`` and its K/V was scattered before the call;
    attention is causal by absolute position (full attention to the cached
    prefix + causal within the span).  Returns (B, S, H, d).  ``impl``
    resolves EAGERLY like ``paged_gqa_attend`` (jit cache keyed on the
    effective path).
    """
    return _gqa_prefill_jit(q, k_pool, v_pool, block_tables, starts,
                            window=window, softcap=softcap,
                            eff=resolve_prefill_impl(impl))


@functools.partial(jax.jit, static_argnames=("window", "softcap", "eff"))
def _gqa_prefill_jit(q, k_pool, v_pool, block_tables, starts, *,
                     window: int, softcap: float, eff: str) -> jax.Array:
    B, S, H, d = q.shape
    KVH = k_pool.shape[2]
    G = H // KVH
    if eff == "ref":
        return _ref.paged_gqa_prefill_reference(
            q, k_pool, v_pool, block_tables, starts, window=window,
            softcap=softcap)
    qg = q.reshape(B, S, KVH, G, d)
    if eff == "blocked":
        out = _blocked_gqa_prefill(qg, k_pool, v_pool, block_tables,
                                   starts, window=window, softcap=softcap)
        return out.reshape(B, S, H, d)
    # head-group packing: (B, KVH, S*G, d) rows are (token i, group g)
    qp = qg.transpose(0, 2, 1, 3, 4).reshape(B, KVH, S * G, d)
    out = _p.paged_prefill_gqa(qp, k_pool, v_pool, block_tables, starts,
                               groups=G, window=window, softcap=softcap,
                               interpret=eff == "pallas_interpret")
    return out.reshape(B, KVH, S, G, d).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, d)


def _blocked_mla_prefill(q_lat, q_rope, c_pool, kr_pool, tables, starts, *,
                         scale):
    """q_lat (B, S, H, L); q_rope (B, S, H, R) -> (B, S, H, L) fp32."""
    B, S, H, L = q_lat.shape
    bs = c_pool.shape[1]
    ql = q_lat.astype(jnp.float32)
    qr = q_rope.astype(jnp.float32)
    qpos = starts[:, None] + jnp.arange(S)[None]
    n_live = _span_n_live(starts, S, bs)

    def body(j, carry):
        m, l, acc = carry
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)
        cb = c_pool[blk].astype(jnp.float32)             # (B, bs, L)
        krb = kr_pool[blk].astype(jnp.float32)
        s = (jnp.einsum("bshl,btl->bsht", ql, cb)
             + jnp.einsum("bshr,btr->bsht", qr, krb)) * scale
        k_pos = j * bs + jnp.arange(bs)
        mask = (k_pos[None, None, :] <= qpos[:, :, None])[:, :, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bsht,btl->bshl", p, cb)
        return m_new, l, acc

    init = (jnp.full((B, S, H), NEG_INF, jnp.float32),
            jnp.zeros((B, S, H), jnp.float32),
            jnp.zeros((B, S, H, L), jnp.float32))
    m, l, acc = _fold_blocks(n_live, body, init)
    return acc / jnp.maximum(l, 1e-20)[..., None]


def paged_mla_prefill(q_lat: jax.Array, q_rope: jax.Array,
                      c_pool: jax.Array, kr_pool: jax.Array,
                      block_tables: jax.Array, starts: jax.Array, *,
                      scale: float, impl: Optional[str] = None) -> jax.Array:
    """Absorbed MLA span prefill ``probs · c`` over the paged latent cache.

    q_lat/q_rope (B, S, H, ·) -> out_lat (B, S, H, lora) fp32; the caller
    applies W^UV / W^O (see ``repro.core.mla.mla_decode_paged``).
    """
    return _mla_prefill_jit(q_lat, q_rope, c_pool, kr_pool, block_tables,
                            starts, scale=scale,
                            eff=resolve_prefill_impl(impl))


@functools.partial(jax.jit, static_argnames=("scale", "eff"))
def _mla_prefill_jit(q_lat, q_rope, c_pool, kr_pool, block_tables, starts,
                     *, scale: float, eff: str) -> jax.Array:
    B, S, H, L = q_lat.shape
    if eff == "ref":
        return _ref.paged_mla_prefill_reference(
            q_lat, q_rope, c_pool, kr_pool, block_tables, starts,
            scale=scale)
    if eff == "blocked":
        return _blocked_mla_prefill(q_lat, q_rope, c_pool, kr_pool,
                                    block_tables, starts, scale=scale)
    out = _p.paged_prefill_mla(
        q_lat.reshape(B, S * H, L),
        q_rope.reshape(B, S * H, q_rope.shape[-1]),
        c_pool, kr_pool, block_tables, starts, heads=H, scale=scale,
        interpret=eff == "pallas_interpret")
    return out.reshape(B, S, H, L)


def _blocked_indexer_prefill(q_idx, w_head, k_pool, tables, starts):
    """q_idx (B, S, Hi, Di); w_head (B, S, Hi) -> (B, S, mb*bs) fp32."""
    B, S, Hi, Di = q_idx.shape
    bs = k_pool.shape[1]
    mb = tables.shape[1]
    scale = Di ** -0.5
    qf = q_idx.astype(jnp.float32)
    wf = w_head.astype(jnp.float32)
    n_live = _span_n_live(starts, S, bs)

    def body(j, out):
        blk = jax.lax.dynamic_index_in_dim(tables, j, axis=1,
                                           keepdims=False)
        kb = k_pool[blk].astype(jnp.float32)             # (B, bs, Di)
        dots = jax.nn.relu(jnp.einsum("bshd,btd->bsht", qf, kb)) * scale
        s = jnp.einsum("bsht,bsh->bst", dots, wf)
        return jax.lax.dynamic_update_slice(out, s, (0, 0, j * bs))

    out0 = jnp.full((B, S, mb * bs), NEG_INF, jnp.float32)
    return _fold_blocks(n_live, body, out0)


def paged_indexer_prefill(q_idx: jax.Array, w_head: jax.Array,
                          k_pool: jax.Array, block_tables: jax.Array,
                          starts: jax.Array, *,
                          impl: Optional[str] = None) -> jax.Array:
    """DSA span indexer scores in view coordinates (B, S, mb*bs) fp32.

    q_idx (B, S, Hi, Di); w_head (B, S, Hi) softmaxed; k_pool (nb, bs, Di).
    Dead blocks score NEG_INF under the in-place impls and stale values
    under ``ref`` — both are excluded by the selector's causal mask.
    """
    return _indexer_prefill_jit(q_idx, w_head, k_pool, block_tables,
                                starts, eff=resolve_prefill_impl(impl))


@functools.partial(jax.jit, static_argnames=("eff",))
def _indexer_prefill_jit(q_idx, w_head, k_pool, block_tables, starts, *,
                         eff: str) -> jax.Array:
    B, S, Hi, Di = q_idx.shape
    if eff == "ref":
        return _ref.paged_indexer_prefill_reference(
            q_idx, w_head, k_pool, block_tables, starts)
    if eff == "blocked":
        return _blocked_indexer_prefill(q_idx, w_head, k_pool, block_tables,
                                        starts)
    return _p.paged_prefill_indexer(
        q_idx.reshape(B, S * Hi, Di), w_head.reshape(B, S * Hi),
        k_pool, block_tables, starts, heads=Hi,
        interpret=eff == "pallas_interpret")
