"""Small shared helpers (abstract-array construction for the dry-run path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zeros(shape, dtype, abstract: bool = False):
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jnp.zeros(shape, dtype)


def stack_tree(tree, n: int, abstract: bool = False):
    """Prepend a leading axis of size n to every leaf."""
    if abstract:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((n,) + tuple(x.shape), x.dtype),
            tree)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)


def abstract_like(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype), tree)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_size(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
