"""Process-wide lowering flags.

``UNROLL_SCANS``: XLA's ``cost_analysis()`` counts a while-loop body ONCE,
not times its trip count, so scanned layers / chunked attention would
under-report FLOPs and bytes by 30-100x in the roofline.  The dry-run driver
sets this True to lower with unrolled scans (identical math, accurate
accounting, slower compile).  Training/serving keep scans (fast compile).

Time-recurrences (mamba selective scan) stay scanned even when set — their
FLOPs are corrected analytically in the roofline report (see
EXPERIMENTS.md §Roofline notes).
"""
import os

UNROLL_SCANS = False


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if UNROLL_SCANS else 1


def paged_attention_impl() -> str:
    """Default decode impl for the paged-attention ops ('pallas' | 'ref').

    'pallas' means *read KV blocks in place* — the Pallas kernel on TPU, an
    O(live-tokens) XLA twin elsewhere (see repro.kernels.paged_attention.ops
    for the full dispatch, incl. JAX_PALLAS_INTERPRET=1).  'ref' restores
    the full-view gather path.  The ops resolve this EAGERLY per call (the
    jit cache is keyed on the resolved path); a ContinuousEngine snapshots
    it at construction for its stats and bakes it into its per-instance
    jits on first trace — flip the env before constructing the engine.
    """
    return os.environ.get("REPRO_PAGED_ATTN_IMPL", "pallas")


def default_spec_steps() -> int:
    """Default MTP speculative draft depth for ``ContinuousEngine``.

    ``REPRO_SPEC_STEPS`` (int, default 0 = speculation off) is used when
    an engine is constructed with ``spec_steps=None`` — one env flips a
    whole serving deployment to speculative decode (greedy-only; the
    engine validates the config has an MTP head).  An explicit
    ``spec_steps=`` always wins.
    """
    return int(os.environ.get("REPRO_SPEC_STEPS", "0"))


def frontend_wait_s() -> float:
    """Idle-wait granularity of the ``AsyncFrontend`` serve thread.

    When the engine has no work the serve thread parks on its condition
    variable and re-checks at this cadence (``REPRO_FRONTEND_WAIT_S``,
    seconds, default 0.05).  Submissions/pushes notify the condition
    immediately, so this only bounds wakeup latency against lost
    notifications — it is NOT a polling tax on the hot path (a busy
    engine steps back-to-back without waiting)."""
    return float(os.environ.get("REPRO_FRONTEND_WAIT_S", "0.05"))


def trace_enabled() -> bool:
    """Process-wide default for serving trace capture (``REPRO_TRACE``).

    ``1``/``true``/``yes`` turns every newly-constructed engine's
    ``Tracer`` on (per-request lifecycle + engine-step spans, exportable
    as Chrome trace-event JSON — see ``repro.obs.trace``).  Off (the
    default) the tracer hooks are single attribute checks: no buffer
    growth, no timestamps, byte-identical serving behavior.  An explicit
    ``ContinuousEngine(tracer=...)`` always wins.
    """
    return os.environ.get("REPRO_TRACE", "0").lower() in ("1", "true", "yes")


def trace_buffer_limit() -> int:
    """Max buffered trace events per ``Tracer`` (``REPRO_TRACE_BUFFER``,
    default 200000).  Beyond it new events are counted as dropped instead
    of appended — a trace left on for a long-running serve loop degrades
    to a bounded prefix, never an OOM."""
    return int(os.environ.get("REPRO_TRACE_BUFFER", "200000"))


def admit_steps_window() -> int:
    """Bound on the ``stats["admit_steps"]`` history deque
    (``REPRO_ADMIT_STEPS_WINDOW``, default 4096 admissions).  The old
    unbounded list grew one entry per admission forever — a memory leak
    on a long-running serve loop; the deque keeps the most recent window
    (tests only ever inspect recent admissions)."""
    return int(os.environ.get("REPRO_ADMIT_STEPS_WINDOW", "4096"))


def fault_spec() -> str:
    """Deterministic fault-injection spec (``REPRO_FAULTS``, default "").

    Comma-separated ``point@i`` / ``point@i..j`` / ``point~p`` clauses
    (optionally ``=x`` parameterized) naming the serving stack's
    injection points — see ``repro.faults`` for the grammar and the
    wired points (alloc storms, step exceptions, slow steps, serve-loop
    crashes, rollout-worker crashes).  Empty disables injection: every
    site then costs one attribute check."""
    return os.environ.get("REPRO_FAULTS", "")


def fault_seed() -> int:
    """Seed for probabilistic (``~p``) fault clauses
    (``REPRO_FAULTS_SEED``, default 0).  A (spec, seed) pair replays the
    identical fault sequence — the reproducibility contract the
    fault-injection CI matrix relies on."""
    return int(os.environ.get("REPRO_FAULTS_SEED", "0"))


def max_waiting_default() -> int:
    """Default bound on ``ContinuousEngine``'s waiting queue
    (``REPRO_MAX_WAITING``, default 1024).  Beyond it ``submit`` raises
    the typed ``EngineOverloaded`` instead of growing an unbounded
    backlog — admission backpressure the caller can see and act on.
    An explicit ``max_waiting=`` always wins; ``<= 0`` means unbounded."""
    return int(os.environ.get("REPRO_MAX_WAITING", "1024"))


def admit_window() -> int:
    """Head-of-line scan window for admission (``REPRO_ADMIT_WINDOW``,
    default 4).  When the queue head cannot admit (not enough free
    blocks), the scheduler scans up to this many queued requests behind
    it for a smaller one that fits instead of stalling ALL admission on
    the head (``stats["admit_skips"]`` counts out-of-order admissions).
    0 restores strict FCFS."""
    return int(os.environ.get("REPRO_ADMIT_WINDOW", "4"))


def max_restarts_default() -> int:
    """Bound on ``AsyncFrontend`` supervisor engine restarts
    (``REPRO_MAX_RESTARTS``, default 3).  Each serve-loop crash rebuilds
    the engine and re-queues un-started work; past the bound the
    front-end marks itself crashed and refuses new submissions (a crash
    loop must not masquerade as a healthy server)."""
    return int(os.environ.get("REPRO_MAX_RESTARTS", "3"))


def pd_threshold_default() -> int:
    """Prompt-length cutoff for the disagg router (``REPRO_PD_THRESHOLD``,
    tokens, default 64).  Prompts at least this long take the
    prefill-tier path (prefill remotely, migrate KV blocks, decode on
    the decode tier); shorter prompts prefill colocated on the decode
    engine — the migration overhead only pays for itself on prefills
    long enough to stall decode streams.  An explicit
    ``DisaggServer(pd_threshold=...)`` always wins."""
    return int(os.environ.get("REPRO_PD_THRESHOLD", "64"))


def migrate_timeout_s() -> float:
    """Per-attempt wall-clock budget for one KV-block migration
    (``REPRO_MIGRATE_TIMEOUT_S``, seconds, default 5.0).  An attempt
    that exceeds it counts as failed and consumes one retry; after the
    retry budget the router degrades the request to colocated prefill
    instead of stalling it behind a wedged transfer."""
    return float(os.environ.get("REPRO_MIGRATE_TIMEOUT_S", "5.0"))


def migrate_retries() -> int:
    """Bounded retry budget per migration beyond the first attempt
    (``REPRO_MIGRATE_RETRIES``, default 2).  Exhaustion raises the typed
    ``MigrationFailed``; the disagg router answers with colocated
    fallback, so retries trade latency for migration reuse — they never
    trade away the request."""
    return int(os.environ.get("REPRO_MIGRATE_RETRIES", "2"))


def migrate_backoff_s() -> float:
    """Base backoff between migration retries
    (``REPRO_MIGRATE_BACKOFF_S``, seconds, default 0.01), doubled per
    attempt — a transient fault (one injected ``xfer`` hit, a momentary
    pool squeeze) clears in one cheap beat without hammering the
    engines."""
    return float(os.environ.get("REPRO_MIGRATE_BACKOFF_S", "0.01"))


def tier_restarts_default() -> int:
    """Bound on prefill-TIER respawns by ``DisaggServer``
    (``REPRO_TIER_RESTARTS``, default 2).  Distinct from
    ``REPRO_MAX_RESTARTS`` (the per-frontend supervisor): the prefill
    frontend runs with ``max_restarts=0`` so a crash surfaces as a tier
    outage the router can observe (degraded colocated mode), and the
    DisaggServer owns the respawn/fail-back cycle up to this bound.
    Past it the tier stays down and the server keeps serving colocated
    — degraded forever beats a respawn loop."""
    return int(os.environ.get("REPRO_TIER_RESTARTS", "2"))


def spill_enabled() -> bool:
    """Process-wide default for the host-RAM KV spill tier
    (``REPRO_SPILL_ENABLE``).  ``1``/``true``/``yes`` makes every
    newly-constructed prefix-cached ``ContinuousEngine`` attach a
    ``HostSpillTier`` (``repro.serving.spill``): the radix tree's LRU
    evictor DEMOTES cold leaves to pinned host memory instead of
    forgetting them, and ``PrefixCache.match`` restores spilled prefixes
    on a hit — effective cache capacity beyond HBM.  Spill is byte-exact
    (greedy outputs are byte-identical with the tier on or off).  An
    explicit ``ContinuousEngine(spill=...)`` always wins."""
    return os.environ.get("REPRO_SPILL_ENABLE",
                          "0").lower() in ("1", "true", "yes")


def spill_blocks() -> int:
    """Capacity of the host spill tier in BLOCKS (``REPRO_SPILL_BLOCKS``,
    default 512).  Beyond it the OLDEST spilled entry is dropped
    (``spill.dropped_capacity``) — host memory is a bigger tier, not an
    unbounded one.  ``<= 0`` means unbounded (tests only).  An explicit
    ``ContinuousEngine(spill_blocks=...)`` always wins."""
    return int(os.environ.get("REPRO_SPILL_BLOCKS", "512"))


def paged_prefill_impl() -> str:
    """Default PREFILL impl for the paged-attention ops ('pallas' | 'ref').

    Mirrors ``paged_attention_impl`` for multi-token spans: 'pallas' runs
    the paged flash-prefill kernels (block-table index maps, no padded-view
    gather; Pallas on TPU / interpret under JAX_PALLAS_INTERPRET=1 / the
    O(live) XLA twin elsewhere), 'ref' restores the ``paged_view`` gather.
    ``REPRO_PAGED_PREFILL_IMPL`` overrides; it falls back to
    ``REPRO_PAGED_ATTN_IMPL`` so one env flips the whole engine step.
    """
    return os.environ.get("REPRO_PAGED_PREFILL_IMPL",
                          os.environ.get("REPRO_PAGED_ATTN_IMPL", "pallas"))
