"""Process-wide lowering flags.

``UNROLL_SCANS``: XLA's ``cost_analysis()`` counts a while-loop body ONCE,
not times its trip count, so scanned layers / chunked attention would
under-report FLOPs and bytes by 30-100x in the roofline.  The dry-run driver
sets this True to lower with unrolled scans (identical math, accurate
accounting, slower compile).  Training/serving keep scans (fast compile).

Time-recurrences (mamba selective scan) stay scanned even when set — their
FLOPs are corrected analytically in the roofline report (see
EXPERIMENTS.md §Roofline notes).
"""
import os

UNROLL_SCANS = False


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if UNROLL_SCANS else 1


def paged_attention_impl() -> str:
    """Default decode impl for the paged-attention ops ('pallas' | 'ref').

    'pallas' means *read KV blocks in place* — the Pallas kernel on TPU, an
    O(live-tokens) XLA twin elsewhere (see repro.kernels.paged_attention.ops
    for the full dispatch, incl. JAX_PALLAS_INTERPRET=1).  'ref' restores
    the full-view gather path.  The ops resolve this EAGERLY per call (the
    jit cache is keyed on the resolved path); a ContinuousEngine snapshots
    it at construction for its stats and bakes it into its per-instance
    jits on first trace — flip the env before constructing the engine.
    """
    return os.environ.get("REPRO_PAGED_ATTN_IMPL", "pallas")


def default_spec_steps() -> int:
    """Default MTP speculative draft depth for ``ContinuousEngine``.

    ``REPRO_SPEC_STEPS`` (int, default 0 = speculation off) is used when
    an engine is constructed with ``spec_steps=None`` — one env flips a
    whole serving deployment to speculative decode (greedy-only; the
    engine validates the config has an MTP head).  An explicit
    ``spec_steps=`` always wins.
    """
    return int(os.environ.get("REPRO_SPEC_STEPS", "0"))


def frontend_wait_s() -> float:
    """Idle-wait granularity of the ``AsyncFrontend`` serve thread.

    When the engine has no work the serve thread parks on its condition
    variable and re-checks at this cadence (``REPRO_FRONTEND_WAIT_S``,
    seconds, default 0.05).  Submissions/pushes notify the condition
    immediately, so this only bounds wakeup latency against lost
    notifications — it is NOT a polling tax on the hot path (a busy
    engine steps back-to-back without waiting)."""
    return float(os.environ.get("REPRO_FRONTEND_WAIT_S", "0.05"))


def trace_enabled() -> bool:
    """Process-wide default for serving trace capture (``REPRO_TRACE``).

    ``1``/``true``/``yes`` turns every newly-constructed engine's
    ``Tracer`` on (per-request lifecycle + engine-step spans, exportable
    as Chrome trace-event JSON — see ``repro.obs.trace``).  Off (the
    default) the tracer hooks are single attribute checks: no buffer
    growth, no timestamps, byte-identical serving behavior.  An explicit
    ``ContinuousEngine(tracer=...)`` always wins.
    """
    return os.environ.get("REPRO_TRACE", "0").lower() in ("1", "true", "yes")


def trace_buffer_limit() -> int:
    """Max buffered trace events per ``Tracer`` (``REPRO_TRACE_BUFFER``,
    default 200000).  Beyond it new events are counted as dropped instead
    of appended — a trace left on for a long-running serve loop degrades
    to a bounded prefix, never an OOM."""
    return int(os.environ.get("REPRO_TRACE_BUFFER", "200000"))


def admit_steps_window() -> int:
    """Bound on the ``stats["admit_steps"]`` history deque
    (``REPRO_ADMIT_STEPS_WINDOW``, default 4096 admissions).  The old
    unbounded list grew one entry per admission forever — a memory leak
    on a long-running serve loop; the deque keeps the most recent window
    (tests only ever inspect recent admissions)."""
    return int(os.environ.get("REPRO_ADMIT_STEPS_WINDOW", "4096"))


def paged_prefill_impl() -> str:
    """Default PREFILL impl for the paged-attention ops ('pallas' | 'ref').

    Mirrors ``paged_attention_impl`` for multi-token spans: 'pallas' runs
    the paged flash-prefill kernels (block-table index maps, no padded-view
    gather; Pallas on TPU / interpret under JAX_PALLAS_INTERPRET=1 / the
    O(live) XLA twin elsewhere), 'ref' restores the ``paged_view`` gather.
    ``REPRO_PAGED_PREFILL_IMPL`` overrides; it falls back to
    ``REPRO_PAGED_ATTN_IMPL`` so one env flips the whole engine step.
    """
    return os.environ.get("REPRO_PAGED_PREFILL_IMPL",
                          os.environ.get("REPRO_PAGED_ATTN_IMPL", "pallas"))
