"""Process-wide lowering flags.

``UNROLL_SCANS``: XLA's ``cost_analysis()`` counts a while-loop body ONCE,
not times its trip count, so scanned layers / chunked attention would
under-report FLOPs and bytes by 30-100x in the roofline.  The dry-run driver
sets this True to lower with unrolled scans (identical math, accurate
accounting, slower compile).  Training/serving keep scans (fast compile).

Time-recurrences (mamba selective scan) stay scanned even when set — their
FLOPs are corrected analytically in the roofline report (see
EXPERIMENTS.md §Roofline notes).
"""
UNROLL_SCANS = False


def scan_unroll():
    """Value to pass as lax.scan(..., unroll=...)."""
    return True if UNROLL_SCANS else 1
