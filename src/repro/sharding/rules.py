"""Logical-axis sharding rules (MaxText-style) + param Builder.

Every parameter is created through :class:`Builder`, which records a tuple of
*logical axis names* per array dimension alongside the initialized array.  At
jit time the logical names are resolved to mesh axes through a rules table,
with an automatic divisibility check: if a dimension is not divisible by the
mesh-axis size the sharding silently falls back to replication (e.g. gemma2's
8 query heads on a 16-way 'model' axis) — this keeps every arch lowerable on
the fixed production mesh while sharding everything that *can* be sharded.

FSDP (ZeRO-3 analogue of the paper's §2.4.1 sharded grads/optimizer states)
is expressed by mapping the ``embed``/``fsdp`` logical axes onto the 'data'
mesh axis.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicate).
# Mesh axes that do not exist in the current mesh are dropped at resolve time.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,            # context-sharded over 'data' for long-decode
    "embed": None,
    "embed_fsdp": "data",      # param d_model dim under FSDP
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "moe_mlp": None,
    "layers": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "index_heads": None,
    "topk": None,
    "lora": None,
}


def make_rules(mesh: Mesh, *, fsdp: bool = True,
               context_parallel_kv: bool = False,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    rules = dict(DEFAULT_RULES)
    if not fsdp:
        rules["embed_fsdp"] = None
    if context_parallel_kv:
        rules["kv_seq"] = "data"
        rules["batch"] = "pod" if "pod" in mesh.axis_names else None
    if overrides:
        rules.update(overrides)
    return rules


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_mesh_axis_size(mesh, a) for a in axis)
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)


def resolve_spec(logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int],
                 rules: Dict[str, Any],
                 mesh: Mesh) -> P:
    """Logical axes + concrete shape -> PartitionSpec with divisibility guard."""
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        axis = rules.get(name) if name else None
        # drop mesh axes that don't exist in this mesh
        if isinstance(axis, (tuple, list)):
            axis = tuple(a for a in axis if a in mesh.axis_names)
            axis = axis if axis else None
            if isinstance(axis, tuple) and len(axis) == 1:
                axis = axis[0]
        elif axis is not None and axis not in mesh.axis_names:
            axis = None
        # divisibility + single-use guards
        if axis is not None:
            size = _mesh_axis_size(mesh, axis)
            flat = tuple(axis) if isinstance(axis, tuple) else (axis,)
            if dim % size != 0 or any(a in used for a in flat):
                axis = None
            else:
                used.update(flat)
        parts.append(axis)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(params: Any, specs: Any, rules: Dict[str, Any],
                   mesh: Mesh) -> Any:
    """Map a (params, logical-spec) tree pair to NamedShardings."""
    def one(leaf, axes):
        shape = leaf.shape if hasattr(leaf, "shape") else np.shape(leaf)
        return NamedSharding(mesh, resolve_spec(axes, shape, rules, mesh))
    return jax.tree.map(one, params, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]],
              rules: Optional[Dict[str, Any]], mesh: Optional[Mesh]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without mesh/rules."""
    if mesh is None or rules is None or not _in_jit_with_mesh(mesh):
        return x
    spec = resolve_spec(logical_axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _in_jit_with_mesh(mesh: Mesh) -> bool:
    return mesh is not None and not mesh.empty


def constrain_batch_seq(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    """Megatron-SP analogue: residual stream sharded over BOTH batch
    ('pod','data') and sequence ('model') between blocks.  XLA then lowers
    the TP boundary as all-gather(seq) + reduce-scatter(seq) instead of two
    full all-reduces — ~half the wire bytes, and norms compute on 1/16 of
    the tokens per rank (beyond-paper optimization; see EXPERIMENTS §Perf).
    """
    if mesh is None or getattr(mesh, "empty", True) or x.ndim < 2:
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes or "model" not in mesh.axis_names:
        return constrain_batch(x, mesh)
    bsz = math.prod(_mesh_axis_size(mesh, a) for a in axes)
    msz = _mesh_axis_size(mesh, "model")
    if x.shape[0] % bsz != 0 or x.shape[1] % msz != 0:
        return constrain_batch(x, mesh)
    spec = P(axes, "model", *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    """Anchor an activation's leading batch dim to the ('pod','data') axes.

    Without this, XLA's sharding propagation can prefer the FSDP weight
    sharding and silently *replicate the batch* (observed: 32k-seq scan
    residuals materialized at global batch on every device).  Called at
    block boundaries; no-op when the batch isn't divisible (e.g. batch=1
    long-decode) or off-mesh."""
    if mesh is None or getattr(mesh, "empty", True):
        return x
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return x
    size = math.prod(_mesh_axis_size(mesh, a) for a in axes)
    if x.ndim == 0 or x.shape[0] % size != 0 or x.shape[0] == 0:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param builder
# ---------------------------------------------------------------------------

class Builder:
    """Collects params + logical axis specs during init.

    ``b.param('wq', (d, h*dh), ('embed_fsdp','heads'), scale=...)`` creates a
    normal-initialized array and records its logical axes.  ``b.sub('attn')``
    opens a nested dict.  ``build_*`` functions in layers/ take a Builder.
    """

    def __init__(self, key: jax.Array, dtype=jnp.float32,
                 abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _next_key(self) -> jax.Array:
        if self.abstract:
            return self._key
        self._key, k = jax.random.split(self._key)
        return k

    def sub(self, name: str) -> "Builder":
        child = Builder(self._next_key(), self.dtype, self.abstract)
        self.params[name] = child.params
        self.specs[name] = child.specs
        return child

    def param(self, name: str, shape: Tuple[int, ...],
              axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: Optional[float] = None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            arr = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
            self.params[name] = arr
            self.specs[name] = tuple(axes)
            return arr
        if init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        elif init == "normal":
            if scale is None:
                fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
                scale = 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(self._next_key(), shape) * scale
                   ).astype(self.dtype)
        elif init == "arange_log":   # mamba A_log init
            n = shape[-1]
            base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            arr = jnp.broadcast_to(base, shape).astype(self.dtype)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.specs[name] = tuple(axes)
        return arr


def stack_init(build_fn: Callable[[Builder], None], n: int, key: jax.Array,
               dtype=jnp.float32, abstract: bool = False) -> Tuple[Dict, Dict]:
    """Initialize ``n`` copies of a layer stacked on a leading 'layers' axis
    (for lax.scan over layers).  Returns (stacked_params, specs)."""
    proto = Builder(jax.random.key(0), dtype, abstract=True)
    build_fn(proto)
    if abstract:
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype),
            proto.params)
    else:
        keys = jax.random.split(key, n)

        def one(k):
            b = Builder(k, dtype)
            build_fn(b)
            return b.params

        params = jax.vmap(one)(keys)
    specs = jax.tree.map(
        lambda axes: ("layers",) + axes, proto.specs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return params, specs


def spec_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
