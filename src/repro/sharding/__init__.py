from repro.sharding.rules import (Builder, DEFAULT_RULES, constrain,
                                  make_rules, resolve_spec, spec_leaf,
                                  stack_init, tree_shardings)

__all__ = ["Builder", "DEFAULT_RULES", "constrain", "make_rules",
           "resolve_spec", "spec_leaf", "stack_init", "tree_shardings"]
